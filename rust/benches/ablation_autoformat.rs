//! Ablation — the entropy/byte-model auto-format policy
//! (`gsem::coordinator::policy`) against the paper's hand-picked
//! GSE-SEM stepped recipe, over both solver corpora. For every matrix
//! the policy decides blind (entropy + traffic model at nrhs 1), then
//! both the decision and the hand-picked ladder run for real. Reports
//! the modeled and measured hand/auto time ratios per matrix, writes
//! the `ablation_autoformat` CSV, and self-asserts that the policy's
//! geomean stays within 5% of the hand-picked recipe on both axes —
//! automatic selection must not give back what the format bought.

#[path = "common.rs"]
mod common;

use gsem::coordinator::policy;
use gsem::coordinator::{FormatChoice, SolverKind};
use gsem::sparse::gen::corpus::{cg_set, gmres_set};
use gsem::util::csv::write_csv;
use gsem::util::stats::geomean;
use gsem::util::table::TextTable;
use std::sync::Arc;

/// Short display label for a resolved choice.
fn choice_label(c: &FormatChoice) -> String {
    match c {
        FormatChoice::Fixed { format, .. } => format.label().to_string(),
        FormatChoice::Stepped { k, .. } => format!("stepped(k={k})"),
        FormatChoice::SteppedCopy { .. } => "stepped-copy".into(),
        FormatChoice::Ir { k } => format!("ir(k={k})"),
        FormatChoice::Auto => "auto".into(),
    }
}

fn main() {
    let size = common::bench_corpus_size();
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut modeled_ratios: Vec<f64> = Vec::new();
    let mut measured_ratios: Vec<f64> = Vec::new();
    let mut fallbacks = 0usize;
    let mut t =
        TextTable::new(&["solver", "matrix", "auto picked", "model hand/auto", "meas hand/auto"]);
    for (solver, sname, set) in
        [(SolverKind::Cg, "cg", cg_set(size)), (SolverKind::Gmres, "gmres", gmres_set(size))]
    {
        // the paper's recipe: the fixed-k stepped GSE ladder every
        // hand-tuned figure uses for this solver family
        let hand = common::solver_formats(solver)
            .into_iter()
            .find(|(label, _)| *label == "GSE-SEM")
            .expect("solver_formats always carries the GSE-SEM ladder")
            .1;
        for m in &set {
            let a = Arc::new(m.a.clone());
            // decide BEFORE any solve runs: the decision must come from
            // the entropy/byte-model tiers alone, not this bench's own
            // switch-log feedback
            let dec = policy::decide(&a, solver, 1);
            if dec.fallback {
                fallbacks += 1;
            }
            let model_auto = policy::modeled_time(&a, &dec.choice, 1);
            let model_hand = policy::modeled_time(&a, &hand, 1);
            let r_model = model_hand / model_auto.max(1e-300);
            let auto_res = common::run_solver_cell(&m.name, &a, solver, dec.choice.clone());
            let hand_res = common::run_solver_cell(&m.name, &a, solver, hand.clone());
            let r_meas = hand_res.outcome.seconds / auto_res.outcome.seconds.max(1e-12);
            modeled_ratios.push(r_model);
            measured_ratios.push(r_meas);
            t.row(&[
                sname.to_string(),
                m.name.clone(),
                choice_label(&dec.choice),
                format!("{r_model:.3}"),
                format!("{r_meas:.3}"),
            ]);
            rows.push(vec![
                sname.to_string(),
                m.name.clone(),
                choice_label(&dec.choice),
                (dec.fallback as u8).to_string(),
                format!("{model_auto:.6e}"),
                format!("{model_hand:.6e}"),
                format!("{:.6e}", auto_res.outcome.seconds),
                format!("{:.6e}", hand_res.outcome.seconds),
                dec.rationale.replace(',', ";"),
            ]);
        }
    }
    t.print();
    let g_model = geomean(&modeled_ratios);
    let g_meas = geomean(&measured_ratios);
    println!(
        "geomean hand/auto: modeled {g_model:.3}  measured {g_meas:.3}  \
         (cells {}, safety fallbacks {fallbacks})",
        modeled_ratios.len()
    );
    let path = write_csv(
        "ablation_autoformat",
        &[
            "solver",
            "matrix",
            "auto_choice",
            "fallback",
            "t_model_auto",
            "t_model_hand",
            "t_meas_auto",
            "t_meas_hand",
            "rationale",
        ],
        &rows,
    )
    .expect("write ablation_autoformat csv");
    println!("wrote {}", path.display());
    // the self-check: automatic selection must stay within 5% of the
    // hand-picked recipe in geomean, on the byte model it ranked with
    // AND on measured wall time
    assert!(
        g_model >= 0.95,
        "auto-format modeled geomean {g_model:.3} fell below 0.95x the hand-picked ladder"
    );
    assert!(
        g_meas >= 0.95,
        "auto-format measured geomean {g_meas:.3} fell below 0.95x the hand-picked ladder"
    );
}
