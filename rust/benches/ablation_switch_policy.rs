//! Ablation — the stepped controller's switch policy (§III-D):
//! each condition disabled in turn, plus window/period sweeps, on hard
//! CG systems where head-only stalls. Reports iterations, final
//! residual, and when the switches fired — the evidence behind the
//! three-condition design of Algorithm 3.

#[path = "common.rs"]
mod common;

use gsem::formats::Precision;
use gsem::solvers::cg::{cg_solve, CgOpts};
use gsem::solvers::ladder::PrecisionSwitchable;
use gsem::solvers::stepped::{PrecisionController, SteppedParams, SwitchableOp};
use gsem::sparse::gen::fem::diffusion2d;
use gsem::spmv::GseCsr;
use gsem::util::csv::write_csv;
use gsem::util::table::TextTable;

/// Which conditions are active.
#[derive(Clone, Copy, Debug)]
struct Policy {
    c1: bool,
    c2: bool,
    c3: bool,
    label: &'static str,
}

fn run_policy(
    a: &gsem::sparse::Csr,
    params: SteppedParams,
    pol: Policy,
) -> (usize, f64, Vec<usize>) {
    let g = GseCsr::from_csr(a, 8);
    let op = SwitchableOp::new(g);
    let mut ctrl = PrecisionController::new(params);
    let ones = vec![1.0; a.ncols];
    let mut b = vec![0.0; a.nrows];
    gsem::spmv::fp64::spmv(a, &ones, &mut b);
    let mut switch_iters = Vec::new();
    let out = {
        let opref = &op;
        let ctrl = &mut ctrl;
        let sw = &mut switch_iters;
        cg_solve(
            opref,
            &b,
            &CgOpts {
                tol: 1e-6,
                max_iters: if common::fast() { 1200 } else { 4000 },
                inv_diag: None,
            },
            move |iter, resid| {
                // replicate PrecisionController::observe but with
                // conditions masked by the policy
                if let Some(_tag) = observe_masked(ctrl, iter, resid, pol) {
                    opref.set_tag(ctrl.tag);
                    sw.push(iter);
                    gsem::solvers::MonitorCmd::Restart
                } else {
                    gsem::solvers::MonitorCmd::Continue
                }
            },
        )
    };
    // residual against the full-precision operator
    let full = op.m.as_ref().clone().at_level(Precision::Full);
    let rel = gsem::solvers::true_relres(&full, &out.x, &b);
    (out.iters, rel, switch_iters)
}

/// PrecisionController::observe with selectable conditions.
fn observe_masked(
    c: &mut PrecisionController,
    iter: usize,
    resid: f64,
    pol: Policy,
) -> Option<u8> {
    use gsem::solvers::stepped::window_metrics;
    // maintain the window manually (mirror of the real controller)
    let got = c.observe(iter, resid);
    match got {
        None => None,
        Some(tag) => {
            // the real controller switched; check whether the masked
            // policy would have: recompute on the pre-clear state is not
            // possible, so approximate by re-deriving from the reason.
            let reason = *c.reasons.last().unwrap();
            let allowed = match reason {
                gsem::solvers::stepped::SwitchReason::Fluctuating => pol.c1,
                gsem::solvers::stepped::SwitchReason::SlowDecrease => pol.c2,
                gsem::solvers::stepped::SwitchReason::NoDecrease => pol.c3,
                // the safety valve is part of every policy
                gsem::solvers::stepped::SwitchReason::Diverged => true,
            };
            let _ = window_metrics; // metrics derived inside observe
            if allowed {
                Some(tag)
            } else {
                // undo the escalation the unmasked controller performed
                c.tag = c.tag.saturating_sub(1).max(1);
                c.switches.pop();
                c.reasons.pop();
                None
            }
        }
    }
}

fn main() {
    let systems = [
        ("contrast14", diffusion2d(28, 28, 14.0, 31)),
        ("contrast18", diffusion2d(24, 24, 18.0, 77)),
    ];
    let params = SteppedParams {
        l: 40,
        t: 24,
        m: 12,
        rsd_limit: 0.5,
        ndec_limit: 12,
        reldec_limit: 0.45,
        divergence_factor: 100.0,
    };
    let policies = [
        Policy { c1: true, c2: true, c3: true, label: "all (paper)" },
        Policy { c1: false, c2: true, c3: true, label: "-C1 fluctuation" },
        Policy { c1: true, c2: false, c3: true, label: "-C2 slow-decrease" },
        Policy { c1: true, c2: true, c3: false, label: "-C3 stagnation" },
        Policy { c1: false, c2: false, c3: false, label: "never switch" },
    ];

    let mut t = TextTable::new(&["system", "policy", "iters", "relres(full)", "switch iters"]);
    let mut rows = Vec::new();
    for (name, a) in &systems {
        for pol in policies {
            let (iters, rel, sw) = run_policy(a, params, pol);
            t.row(&[
                name.to_string(),
                pol.label.to_string(),
                iters.to_string(),
                format!("{rel:.3e}"),
                format!("{sw:?}"),
            ]);
            rows.push(vec![
                name.to_string(),
                pol.label.to_string(),
                iters.to_string(),
                format!("{rel:.6e}"),
                format!("{}", sw.len()),
            ]);
        }
    }
    println!("Ablation — stepped-switch policy (CG, hard diffusion systems)");
    t.print();
    let _ = write_csv(
        "ablation_switch_policy",
        &["system", "policy", "iters", "relres", "n_switches"],
        &rows,
    );

    // window-length sweep with the full policy
    println!("\nwindow sweep (t, m) with all conditions:");
    let mut t2 = TextTable::new(&["t", "m", "iters", "relres"]);
    for (tw, ms) in [(12, 6), (24, 12), (48, 24), (96, 48)] {
        let p = SteppedParams { t: tw, m: ms, ..params };
        let (iters, rel, _) =
            run_policy(&systems[0].1, p, Policy { c1: true, c2: true, c3: true, label: "" });
        t2.row(&[tw.to_string(), ms.to_string(), iters.to_string(), format!("{rel:.3e}")]);
    }
    t2.print();
}
