//! Ablation — SAINV-preconditioned GMRES-IR vs the plain stepped
//! GMRES controller at a *tight* tolerance (1e-10).
//!
//! The stepped controller (Alg. 3) adapts the operator's precision but
//! leaves the Krylov space unpreconditioned: on ill-scaled systems —
//! circuit conductance networks spanning many binades and random
//! matrices with wide Gaussian exponent laws — restarted GMRES
//! plateaus far above 1e-10 no matter which rung it runs on. GMRES-IR
//! with registry-resident SAINV factors solves the *preconditioned*
//! system on a cheap rung and polishes with FP64 outer residual
//! corrections, so the same encode reaches the tight tolerance.
//!
//! Self-check (CI runs this in fast mode): on at least two instances
//! where stepped GMRES stalls, SAINV GMRES-IR must converge.

#[path = "common.rs"]
mod common;

use gsem::coordinator::{
    FormatChoice, Precond, RhsSpec, SainvParams, ServiceError, SolveRequest, SolveResult,
    SolverKind,
};
use gsem::solvers::stepped::SteppedParams;
use gsem::sparse::csr::Csr;
use gsem::sparse::gen::circuit::conductance_network;
use gsem::sparse::gen::randmat::{exp_controlled, ExpLaw};
use gsem::util::csv::write_csv;
use gsem::util::table::TextTable;
use std::sync::Arc;

const TOL: f64 = 1e-10;

fn instances() -> Vec<(String, Csr)> {
    let n = if common::fast() { 900 } else { 4000 };
    let mut set = Vec::new();
    // circuit networks: lognormal conductances over ever more binades
    for (i, sigma) in [5.0, 7.0, 9.0].iter().enumerate() {
        set.push((
            format!("circuit-s{sigma}"),
            conductance_network(n, 6, *sigma, 0.3, 40 + i as u64),
        ));
    }
    // random matrices with wide Gaussian exponent laws (paper's knob)
    for (i, sigma) in [8.0, 12.0].iter().enumerate() {
        set.push((
            format!("gauss-s{sigma}"),
            exp_controlled(n, n, 7, ExpLaw::Gaussian { e0: 0, sigma: *sigma }, 90 + i as u64),
        ));
    }
    set
}

/// Redeem a dispatch result: breakdowns are chartable data points.
fn redeem(res: Result<SolveResult, ServiceError>) -> SolveResult {
    match res {
        Ok(r) => r,
        Err(ServiceError::Breakdown(b)) => *b,
        Err(e) => panic!("unexpected dispatch error: {e}"),
    }
}

fn run(name: &str, a: &Arc<Csr>, format: FormatChoice, precond: Precond) -> SolveResult {
    let mut req = SolveRequest::new(name, Arc::clone(a), SolverKind::Gmres, format);
    req.rhs = RhsSpec::AxOnes;
    req.precond = precond;
    req.tol = TOL;
    req.max_iters = if common::fast() { 2400 } else { 9600 };
    redeem(gsem::coordinator::jobs::dispatch(&req))
}

fn main() {
    let set = instances();
    eprintln!("ablation_precond: {} instances, tol {TOL:.0e}", set.len());
    let stepped = SteppedParams::gmres_paper().scaled(if common::fast() { 0.005 } else { 0.02 });

    let mut t = TextTable::new(&[
        "matrix",
        "stepped relres",
        "stepped iters",
        "ir-sainv relres",
        "ir-sainv iters",
        "ir switches",
        "verdict",
    ]);
    let mut rows = Vec::new();
    let mut rescued = 0usize;
    let mut ir_failures = 0usize;
    for (name, a) in &set {
        let a = Arc::new(a.clone());
        let plain = run(name, &a, FormatChoice::Stepped { k: 8, params: stepped }, Precond::None);
        let ir = run(
            name,
            &a,
            FormatChoice::Ir { k: 8 },
            Precond::Sainv(SainvParams { drop_tol: 0.05, k: 8 }),
        );
        let verdict = match (plain.outcome.converged, ir.outcome.converged) {
            (false, true) => {
                rescued += 1;
                "rescued"
            }
            (true, true) => "both",
            (false, false) => {
                ir_failures += 1;
                "neither"
            }
            (true, false) => {
                ir_failures += 1;
                "regressed"
            }
        };
        t.row(&[
            name.clone(),
            plain.outcome.relres_label(),
            format!("{}", plain.outcome.iters),
            ir.outcome.relres_label(),
            format!("{}", ir.outcome.iters),
            format!("{}", ir.outcome.switches.len()),
            verdict.to_string(),
        ]);
        rows.push(vec![
            name.clone(),
            format!("{:.4e}", plain.relres_fp64),
            format!("{}", plain.outcome.iters),
            format!("{:.4e}", ir.relres_fp64),
            format!("{}", ir.outcome.iters),
            format!("{}", ir.outcome.switches.len()),
            verdict.to_string(),
        ]);
    }
    println!("Ablation — SAINV GMRES-IR vs stepped GMRES at tol {TOL:.0e}");
    t.print();
    let _ = write_csv(
        "ablation_precond",
        &[
            "matrix",
            "stepped_relres",
            "stepped_iters",
            "ir_relres",
            "ir_iters",
            "ir_switches",
            "verdict",
        ],
        &rows,
    );
    println!(
        "\nSAINV GMRES-IR converged where the stepped controller stalled on \
         {rescued}/{} instances ({ir_failures} IR failures).",
        set.len()
    );
    // the acceptance self-check: the subsystem must rescue at least two
    // instances the unpreconditioned controller cannot finish
    assert!(
        rescued >= 2,
        "expected SAINV GMRES-IR to converge on >=2 instances where stepped \
         GMRES fails at tol {TOL:.0e}; got {rescued}"
    );
}
