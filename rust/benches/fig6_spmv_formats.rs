//! Fig. 6 — SpMV format comparison across the corpus:
//! (a) GFLOPS of FP64 / FP16 / BF16 / GSE-SEM(head) SpMV (sorted by nnz),
//! (b) max absolute error of the three 16-bit-storage kernels vs FP64.
//!
//! Paper shape: FP16 ≈ BF16 fastest; GSE-SEM(head) beats FP64 on most
//! matrices but trails the plain 16-bit loads (decode overhead); GSE-SEM
//! error is far below FP16/BF16 (exactly 0 on many matrices).

#[path = "common.rs"]
mod common;

use gsem::formats::{Bf16, Fp16, Precision, ValueFormat};
use gsem::sparse::gen::corpus::spmv_corpus;
use gsem::spmv::lowp::LowpCsr;
use gsem::spmv::traffic::V100;
use gsem::spmv::{fp64, max_abs_diff, GseCsr};
use gsem::util::csv::write_csv;
use gsem::util::stats::geomean;
use gsem::util::table::TextTable;

fn main() {
    let mut corpus = spmv_corpus(common::bench_corpus_size());
    corpus.sort_by_key(|m| m.a.nnz()); // Fig 6(a) sorts by nnz
    eprintln!("fig6: {} matrices x 4 formats", corpus.len());
    let budget = common::cell_budget();

    let mut rows = Vec::new();
    let mut gf = vec![Vec::new(); 4]; // cpu gflops per format
    let mut errs = vec![Vec::new(); 3]; // fp16, bf16, gse
    let mut zero_err_gse = 0usize;

    for m in &corpus {
        let a = &m.a;
        let flops = 2.0 * a.nnz() as f64;
        let x = vec![1.0; a.ncols];
        let mut y64 = vec![0.0; a.nrows];
        fp64::spmv(a, &x, &mut y64);

        let h16 = LowpCsr::<Fp16>::from_csr(a);
        let b16 = LowpCsr::<Bf16>::from_csr(a);
        let gse = GseCsr::from_csr(a, 8);

        let t64 = common::quick_time(budget, || {
            let mut y = vec![0.0; a.nrows];
            fp64::spmv(a, &x, &mut y);
            y
        });
        let t16 = common::quick_time(budget, || {
            let mut y = vec![0.0; a.nrows];
            h16.spmv(&x, &mut y);
            y
        });
        let tb = common::quick_time(budget, || {
            let mut y = vec![0.0; a.nrows];
            b16.spmv(&x, &mut y);
            y
        });
        let tg = common::quick_time(budget, || {
            let mut y = vec![0.0; a.nrows];
            gse.spmv(&x, &mut y, Precision::Head);
            y
        });

        let mut yh = vec![0.0; a.nrows];
        h16.spmv(&x, &mut yh);
        let mut yb = vec![0.0; a.nrows];
        b16.spmv(&x, &mut yb);
        let mut yg = vec![0.0; a.nrows];
        gse.spmv(&x, &mut yg, Precision::Head);
        let (e16, eb, eg) =
            (max_abs_diff(&y64, &yh), max_abs_diff(&y64, &yb), max_abs_diff(&y64, &yg));
        if eg == 0.0 {
            zero_err_gse += 1;
        }

        for (i, t) in [t64, t16, tb, tg].iter().enumerate() {
            gf[i].push(flops / t / 1e9);
        }
        errs[0].push(e16);
        errs[1].push(eb);
        errs[2].push(eg);
        rows.push(vec![
            m.name.clone(),
            a.nnz().to_string(),
            format!("{:.4}", flops / t64 / 1e9),
            format!("{:.4}", flops / t16 / 1e9),
            format!("{:.4}", flops / tb / 1e9),
            format!("{:.4}", flops / tg / 1e9),
            format!("{e16:.4e}"),
            format!("{eb:.4e}"),
            format!("{eg:.4e}"),
        ]);
    }
    let _ = write_csv(
        "fig6_spmv_formats",
        &[
            "matrix",
            "nnz",
            "gflops_fp64",
            "gflops_fp16",
            "gflops_bf16",
            "gflops_gse_head",
            "err_fp16",
            "err_bf16",
            "err_gse",
        ],
        &rows,
    );

    println!("Fig. 6(a) — geomean SpMV GFLOPS (CPU measured | V100 modeled)");
    let mut t =
        TextTable::new(&["format", "cpu geomean GFLOPS", "V100 modeled GFLOPS (median mtx)"]);
    let mid = &corpus[corpus.len() / 2].a;
    for (i, (label, vf)) in [
        ("FP64", ValueFormat::Fp64),
        ("FP16", ValueFormat::Fp16),
        ("BF16", ValueFormat::Bf16),
        ("GSE-SEM(head)", ValueFormat::GseSem(Precision::Head)),
    ]
    .iter()
    .enumerate()
    {
        t.row(&[
            label.to_string(),
            format!("{:.3}", geomean(&gf[i])),
            format!("{:.1}", V100.spmv_gflops(mid, *vf)),
        ]);
    }
    t.print();

    println!("\nFig. 6(b) — error vs FP64 (x = 1)");
    let mut t = TextTable::new(&["format", "median maxAbsErr", "mean maxAbsErr", "share err==0"]);
    for (i, label) in ["FP16", "BF16", "GSE-SEM(head)"].iter().enumerate() {
        let zero = errs[i].iter().filter(|&&e| e == 0.0).count();
        t.row(&[
            label.to_string(),
            format!("{:.3e}", gsem::util::stats::median(&errs[i])),
            format!("{:.3e}", gsem::util::stats::mean(&errs[i])),
            format!("{:.1}%", 100.0 * zero as f64 / errs[i].len() as f64),
        ]);
    }
    t.print();
    println!(
        "paper: GSE-SEM matches FP64 exactly on the first ~97/300 matrices \
         (here: {zero_err_gse}/{}), while FP16/BF16 errors reach 10..100.",
        corpus.len()
    );
}
