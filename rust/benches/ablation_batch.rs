//! Ablation — batched multi-RHS SpMV throughput (the §III-C traffic
//! argument applied to batching): SpMV is memory-bound and the matrix
//! bytes dominate, so a fused `apply_multi` that decodes each matrix
//! row **once** and streams it across all right-hand sides should beat
//! `nrhs` looped single-RHS applies on per-RHS wall time — most of all
//! for the decode-heavy GSE-SEM levels. This bench measures exactly
//! that, per storage format and batch width, against the looped
//! baseline (`apply_multi_looped`).

#[path = "common.rs"]
mod common;

use gsem::formats::{Precision, ValueFormat};
use gsem::sparse::gen::corpus::{spmv_corpus, NamedMatrix};
use gsem::spmv::{apply_multi_looped, build_operators, SpmvOp};
use gsem::util::csv::write_csv;
use gsem::util::stats::geomean;
use gsem::util::table::TextTable;

fn main() {
    let mut corpus = spmv_corpus(common::bench_corpus_size());
    corpus.sort_by_key(|m| m.a.nnz());
    // the largest few matrices give the stablest per-RHS timings
    let picks: Vec<&NamedMatrix> = corpus.iter().rev().take(3).collect();
    eprintln!("ablation_batch: {} matrices", picks.len());
    let budget = common::cell_budget();
    let widths = [1usize, 2, 4, 8];

    let header = ["matrix", "format", "nrhs", "looped/rhs", "fused/rhs", "speedup"];
    let mut t = TextTable::new(&header);
    let mut rows = Vec::new();
    // (looped, fused) per-RHS seconds at nrhs=8 for the GSE head level
    let mut head8: Vec<(f64, f64)> = Vec::new();
    for m in &picks {
        let a = &m.a;
        let ops: Vec<Box<dyn SpmvOp>> = build_operators(a, 8);
        for op in &ops {
            for &nrhs in &widths {
                let x: Vec<f64> = (0..a.ncols * nrhs).map(|i| ((i % 9) as f64) - 4.0).collect();
                let mut y = vec![0.0; a.nrows * nrhs];
                let t_loop = common::quick_time(budget, || {
                    apply_multi_looped(op.as_ref(), &x, &mut y, nrhs);
                });
                let t_fused = common::quick_time(budget, || {
                    op.apply_multi(&x, &mut y, nrhs);
                });
                let (lp, fp) = (t_loop / nrhs as f64, t_fused / nrhs as f64);
                if op.format() == ValueFormat::GseSem(Precision::Head) && nrhs == 8 {
                    head8.push((lp, fp));
                }
                t.row(&[
                    m.name.clone(),
                    op.format().label().to_string(),
                    nrhs.to_string(),
                    format!("{:.3}us", lp * 1e6),
                    format!("{:.3}us", fp * 1e6),
                    format!("{:.2}x", lp / fp),
                ]);
                rows.push(vec![
                    m.name.clone(),
                    op.format().label().to_string(),
                    nrhs.to_string(),
                    format!("{lp:.4e}"),
                    format!("{fp:.4e}"),
                ]);
            }
        }
    }
    println!("Ablation — per-RHS SpMV time, fused apply_multi vs looped single applies");
    t.print();
    let _ = write_csv(
        "ablation_batch",
        &["matrix", "format", "nrhs", "t_looped_per_rhs", "t_fused_per_rhs"],
        &rows,
    );

    let speedups: Vec<f64> = head8.iter().map(|&(l, f)| l / f).collect();
    let wins = head8.iter().filter(|&&(l, f)| f < l).count();
    println!(
        "\nGSE-SEM(head) @ nrhs=8: fused beats 8x looped on {}/{} matrices \
         (geomean per-RHS speedup {:.2}x)",
        wins,
        head8.len(),
        geomean(&speedups)
    );
}
