//! Ablation — batched multi-RHS SpMV throughput (the §III-C traffic
//! argument applied to batching): SpMV is memory-bound and the matrix
//! bytes dominate, so a fused `apply_multi` that decodes each matrix
//! row **once** and streams it across all right-hand sides should beat
//! `nrhs` looped single-RHS applies on per-RHS wall time — most of all
//! for the decode-heavy GSE-SEM levels. This bench measures exactly
//! that, per storage format and batch width, against the looped
//! baseline (`apply_multi_looped`) — and reports each cell's achieved
//! GB/s (the `spmv::traffic` byte model over measured fused time)
//! against a STREAM-triad roofline measured on this machine, so the
//! "memory-bound" premise is legible as a fraction of peak.
//!
//! The largest (smoke) matrix doubles as a regression guard: fused must
//! not lose to looped at nrhs >= 4 (geomean across formats), so a tile
//! kernel regression fails this bench loudly in CI.

#[path = "common.rs"]
mod common;

use gsem::formats::{Precision, ValueFormat};
use gsem::sparse::gen::corpus::{spmv_corpus, NamedMatrix};
use gsem::spmv::traffic::V100;
use gsem::spmv::{apply_multi_looped, build_operators, SpmvOp};
use gsem::util::csv::write_csv;
use gsem::util::stats::geomean;
use gsem::util::table::TextTable;

fn main() {
    let mut corpus = spmv_corpus(common::bench_corpus_size());
    corpus.sort_by_key(|m| m.a.nnz());
    // the largest few matrices give the stablest per-RHS timings
    let picks: Vec<&NamedMatrix> = corpus.iter().rev().take(3).collect();
    let bw = common::stream_triad_bw();
    eprintln!(
        "ablation_batch: {} matrices, STREAM triad roofline {:.2} GB/s",
        picks.len(),
        bw / 1e9
    );
    let budget = common::cell_budget();
    let widths = [1usize, 2, 4, 8];

    let header =
        ["matrix", "format", "nrhs", "looped/rhs", "fused/rhs", "speedup", "GB/s", "roof%"];
    let mut t = TextTable::new(&header);
    let mut rows = Vec::new();
    let mut roof_rows = Vec::new();
    // (looped, fused) per-RHS seconds at nrhs=8 for the GSE head level
    let mut head8: Vec<(f64, f64)> = Vec::new();
    // fused-vs-looped speedups on the largest (smoke) matrix, nrhs >= 4
    let mut guard: Vec<f64> = Vec::new();
    for (mi, m) in picks.iter().enumerate() {
        let a = &m.a;
        let ops: Vec<Box<dyn SpmvOp>> = build_operators(a, 8);
        for op in &ops {
            for &nrhs in &widths {
                let x: Vec<f64> = (0..a.ncols * nrhs).map(|i| ((i % 9) as f64) - 4.0).collect();
                let mut y = vec![0.0; a.nrows * nrhs];
                let t_loop = common::quick_time(budget, || {
                    apply_multi_looped(op.as_ref(), &x, &mut y, nrhs);
                });
                let t_fused = common::quick_time(budget, || {
                    op.apply_multi(&x, &mut y, nrhs);
                });
                let (lp, fp) = (t_loop / nrhs as f64, t_fused / nrhs as f64);
                // achieved bandwidth of the fused kernel: modeled bytes
                // (matrix planes once + per-RHS vector traffic) over
                // measured wall time, as a fraction of the STREAM roof
                let bytes = V100.spmv_multi_bytes(a.nnz(), a.nrows, op.format(), nrhs);
                let gbs = bytes / t_fused / 1e9;
                let roof = gbs * 1e9 / bw * 100.0;
                if op.format() == ValueFormat::GseSem(Precision::Head) && nrhs == 8 {
                    head8.push((lp, fp));
                }
                if mi == 0 && nrhs >= 4 {
                    guard.push(lp / fp);
                }
                t.row(&[
                    m.name.clone(),
                    op.format().label().to_string(),
                    nrhs.to_string(),
                    format!("{:.3}us", lp * 1e6),
                    format!("{:.3}us", fp * 1e6),
                    format!("{:.2}x", lp / fp),
                    format!("{gbs:.2}"),
                    format!("{roof:.1}"),
                ]);
                rows.push(vec![
                    m.name.clone(),
                    op.format().label().to_string(),
                    nrhs.to_string(),
                    format!("{lp:.4e}"),
                    format!("{fp:.4e}"),
                    format!("{gbs:.4e}"),
                    format!("{roof:.2}"),
                ]);
                roof_rows.push(vec![
                    m.name.clone(),
                    op.format().label().to_string(),
                    nrhs.to_string(),
                    format!("{bytes:.4e}"),
                    format!("{gbs:.4e}"),
                    format!("{:.4e}", bw / 1e9),
                    format!("{roof:.2}"),
                ]);
            }
        }
    }
    println!("Ablation — per-RHS SpMV time, fused apply_multi vs looped single applies");
    println!("(GB/s = modeled fused-kernel bytes / measured time; roof% vs STREAM triad)");
    t.print();
    let _ = write_csv(
        "ablation_batch",
        &[
            "matrix",
            "format",
            "nrhs",
            "t_looped_per_rhs",
            "t_fused_per_rhs",
            "fused_gbs",
            "roof_pct",
        ],
        &rows,
    );
    let _ = write_csv(
        "ablation_batch_roofline",
        &["matrix", "format", "nrhs", "model_bytes", "fused_gbs", "stream_gbs", "roof_pct"],
        &roof_rows,
    );

    let speedups: Vec<f64> = head8.iter().map(|&(l, f)| l / f).collect();
    let wins = head8.iter().filter(|&&(l, f)| f < l).count();
    println!(
        "\nGSE-SEM(head) @ nrhs=8: fused beats 8x looped on {}/{} matrices \
         (geomean per-RHS speedup {:.2}x)",
        wins,
        head8.len(),
        geomean(&speedups)
    );

    // Regression guard: on the smoke matrix the fused tiled kernels
    // must at least match the looped baseline once the batch is wide
    // enough to amortize the matrix stream. Geomean across all formats
    // and widths >= 4, so a single noisy cell cannot flip the verdict —
    // but a real tile-kernel regression fails the bench (and CI) here.
    let g = geomean(&guard);
    println!(
        "fused-vs-looped geomean on {} at nrhs>=4: {:.2}x ({} cells)",
        picks[0].name,
        g,
        guard.len()
    );
    assert!(
        g >= 1.0,
        "fused multi-RHS kernels regressed below the looped baseline: {g:.3}x on {}",
        picks[0].name
    );
}
