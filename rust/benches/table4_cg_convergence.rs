//! Table IV — iterations and relative residuals of CG under FP64 /
//! FP16 / BF16 / GSE-SEM (stepped) on the 15-matrix CG set.
//!
//! Paper shape: FP16 overflows on 10 systems; BF16 stalls at 1e-3..1e-5
//! on the hard ones; GSE-SEM attains the smallest residual on 10/15.

#[path = "common.rs"]
mod common;

use gsem::coordinator::SolverKind;
use gsem::sparse::gen::corpus::cg_set;
use gsem::util::csv::write_csv;
use gsem::util::table::TextTable;

fn main() {
    let set = cg_set(common::bench_corpus_size());
    eprintln!("table4: CG over {} matrices x 4 formats", set.len());
    let grid = common::run_suite(SolverKind::Cg, &set);

    let mut t = TextTable::new(&[
        "ID", "matrix", "it FP64", "it FP16", "it BF16", "it GSE", "res FP64", "res FP16",
        "res BF16", "res GSE",
    ]);
    let mut rows = Vec::new();
    let mut gse_best_res = 0usize;
    let mut fp16_failed = 0usize;
    let mut bf16_stalled = 0usize;
    for (i, (name, rs)) in grid.iter().enumerate() {
        let iters: Vec<String> = rs.iter().map(|r| r.outcome.iters.to_string()).collect();
        let res: Vec<String> = rs.iter().map(|r| r.outcome.relres_label()).collect();
        let lowp: Vec<f64> = rs[1..]
            .iter()
            .map(|r| if r.outcome.broke_down { f64::INFINITY } else { r.relres_fp64 })
            .collect();
        if lowp[2] <= lowp[0] && lowp[2] <= lowp[1] {
            gse_best_res += 1;
        }
        if rs[1].outcome.broke_down || !rs[1].outcome.converged {
            fp16_failed += 1;
        }
        if !rs[2].outcome.converged && !rs[2].outcome.broke_down {
            bf16_stalled += 1;
        }
        t.row(&[
            (i + 1).to_string(),
            name.clone(),
            iters[0].clone(),
            iters[1].clone(),
            iters[2].clone(),
            iters[3].clone(),
            res[0].clone(),
            res[1].clone(),
            res[2].clone(),
            res[3].clone(),
        ]);
        rows.push(vec![
            (i + 1).to_string(),
            name.clone(),
            iters.join("|"),
            rs.iter().map(|r| format!("{:.3e}", r.relres_fp64)).collect::<Vec<_>>().join("|"),
        ]);
    }
    println!("Table IV — CG iterations and relative residuals");
    t.print();
    let _ = write_csv("table4_cg", &["id", "matrix", "iters", "relres"], &rows);
    println!(
        "\nshape: GSE-SEM best 16-bit residual on {gse_best_res}/{} matrices \
         (paper: 10/15); FP16 failed/overflowed on {fp16_failed} (paper: 10); \
         BF16 stalled without converging on {bf16_stalled} (paper: several at 1e-3..1e-5).",
        grid.len()
    );
}
