//! Table III — iterations and relative residuals of GMRES under FP64 /
//! FP16 / BF16 / GSE-SEM (stepped) on the 15-matrix GMRES set.
//!
//! Paper shape: FP16 overflows ("/") on several systems; GSE-SEM attains
//! the smallest residual on the most matrices and converges in fewer
//! iterations than FP64 on some.

#[path = "common.rs"]
mod common;

use gsem::coordinator::SolverKind;
use gsem::sparse::gen::corpus::gmres_set;
use gsem::util::csv::write_csv;
use gsem::util::table::TextTable;

fn main() {
    let set = gmres_set(common::bench_corpus_size());
    eprintln!("table3: GMRES over {} matrices x 4 formats", set.len());
    let grid = common::run_suite(SolverKind::Gmres, &set);

    let mut t = TextTable::new(&[
        "ID", "matrix", "it FP64", "it FP16", "it BF16", "it GSE", "res FP64", "res FP16",
        "res BF16", "res GSE",
    ]);
    let mut rows = Vec::new();
    let mut gse_best_res = 0usize;
    let mut gse_fewer_iters = 0usize;
    let mut fp16_broke = 0usize;
    for (i, (name, rs)) in grid.iter().enumerate() {
        let iters: Vec<String> = rs.iter().map(|r| r.outcome.iters.to_string()).collect();
        let res: Vec<String> = rs.iter().map(|r| r.outcome.relres_label()).collect();
        // who has the smallest residual among the 16-bit formats?
        let lowp: Vec<f64> = rs[1..]
            .iter()
            .map(|r| if r.outcome.broke_down { f64::INFINITY } else { r.relres_fp64 })
            .collect();
        if lowp[2] <= lowp[0] && lowp[2] <= lowp[1] {
            gse_best_res += 1;
        }
        if rs[3].outcome.converged && rs[3].outcome.iters < rs[0].outcome.iters {
            gse_fewer_iters += 1;
        }
        if rs[1].outcome.broke_down {
            fp16_broke += 1;
        }
        t.row(&[
            (i + 1).to_string(),
            name.clone(),
            iters[0].clone(),
            iters[1].clone(),
            iters[2].clone(),
            iters[3].clone(),
            res[0].clone(),
            res[1].clone(),
            res[2].clone(),
            res[3].clone(),
        ]);
        rows.push(vec![
            (i + 1).to_string(),
            name.clone(),
            iters.join("|"),
            rs.iter().map(|r| format!("{:.3e}", r.relres_fp64)).collect::<Vec<_>>().join("|"),
        ]);
    }
    println!("Table III — GMRES iterations and relative residuals");
    t.print();
    let _ = write_csv("table3_gmres", &["id", "matrix", "iters", "relres"], &rows);
    println!(
        "\nshape: GSE-SEM best 16-bit residual on {gse_best_res}/{} matrices \
         (paper: 7/15); fewer iters than FP64 on {gse_fewer_iters} (paper: 10); \
         FP16 overflow on {fp16_broke} (paper: 4).",
        grid.len()
    );
}
