//! End-to-end driver (the DESIGN.md validation workload): solve a real
//! small SPD system — a 96×96 variable-coefficient diffusion problem
//! (9216 unknowns, ~46k nnz) — with every storage format the paper
//! compares, logging per-iteration residual curves to `results/`, and
//! additionally push the same operator through the **AOT Pallas CG
//! artifact via PJRT** to prove all three layers compose.
//!
//! Run: `cargo run --release --example stepped_cg_e2e`

use gsem::coordinator::{FormatChoice, RhsSpec, SolveRequest, SolverKind};
use gsem::formats::{Precision, ValueFormat};
use gsem::solvers::stepped::SteppedParams;
use gsem::sparse::gen::fem::diffusion2d;
use gsem::spmv::ell::to_ell;
use gsem::spmv::GseCsr;
use gsem::util::csv::write_csv;
use gsem::util::table::TextTable;
use std::sync::Arc;

fn main() {
    let a = diffusion2d(96, 96, 12.0, 2024);
    println!(
        "system: 2D heterogeneous diffusion, {} unknowns, {} nnz, contrast 2^12",
        a.nrows,
        a.nnz()
    );
    let arc = Arc::new(a.clone());

    let formats: [(&str, FormatChoice); 7] = [
        ("FP64", FormatChoice::fixed(ValueFormat::Fp64)),
        ("FP16", FormatChoice::fixed(ValueFormat::Fp16)),
        ("BF16", FormatChoice::fixed(ValueFormat::Bf16)),
        ("GSE-head", FormatChoice::fixed(ValueFormat::GseSem(Precision::Head))),
        ("GSE-full", FormatChoice::fixed(ValueFormat::GseSem(Precision::Full))),
        (
            "GSE-stepped",
            FormatChoice::Stepped { k: 8, params: SteppedParams::cg_paper().scaled(0.05) },
        ),
        (
            "FP32->FP64",
            FormatChoice::SteppedCopy { params: SteppedParams::cg_paper().scaled(0.05) },
        ),
    ];

    let mut table =
        TextTable::new(&["format", "iters", "converged", "relres(FP64)", "time(s)", "switches"]);
    let mut curves: Vec<(String, Vec<f64>)> = Vec::new();
    for (label, fmt) in formats {
        let mut req = SolveRequest::new(label, Arc::clone(&arc), SolverKind::Cg, fmt);
        req.rhs = RhsSpec::AxOnes;
        req.max_iters = 4000;
        // keep breakdown rows in the table (the paper's "/" cells)
        let res = match gsem::coordinator::jobs::dispatch(&req) {
            Ok(r) => r,
            Err(gsem::coordinator::ServiceError::Breakdown(b)) => *b,
            Err(e) => panic!("{label}: {e}"),
        };
        table.row(&[
            label.to_string(),
            res.outcome.iters.to_string(),
            res.outcome.converged.to_string(),
            format!("{:.3e}", res.relres_fp64),
            format!("{:.3}", res.outcome.seconds),
            format!("{:?}", res.outcome.switches),
        ]);
        curves.push((label.to_string(), res.outcome.history.clone()));
    }
    table.print();

    // residual curves -> results/e2e_cg_residuals.csv (column per format)
    let maxlen = curves.iter().map(|(_, c)| c.len()).max().unwrap_or(0);
    let header: Vec<&str> =
        std::iter::once("iter").chain(curves.iter().map(|(l, _)| l.as_str())).collect();
    let rows: Vec<Vec<String>> = (0..maxlen)
        .map(|i| {
            std::iter::once((i + 1).to_string())
                .chain(curves.iter().map(|(_, c)| {
                    c.get(i).map(|r| format!("{r:.6e}")).unwrap_or_default()
                }))
                .collect()
        })
        .collect();
    match write_csv("e2e_cg_residuals", &header, &rows) {
        Ok(p) => println!("residual curves -> {}", p.display()),
        Err(e) => eprintln!("csv write failed: {e}"),
    }

    // --- the AOT layer: run the Pallas CG artifact on a 256-dof slice ---
    match gsem::runtime::Engine::load_default() {
        Ok(Some(engine)) if !engine.backend_available() => {
            println!("\n(no PJRT backend in this build; artifacts validated but not executed)")
        }
        Ok(Some(mut engine)) => {
            let small = diffusion2d(16, 16, 8.0, 21);
            let g = GseCsr::from_csr(&small, 8);
            let ell = to_ell(&g, &small, 16);
            let slab = &ell.slabs[0];
            let ones = vec![1.0; 256];
            let mut b = vec![0.0; 256];
            gsem::spmv::fp64::spmv(&small, &ones, &mut b);
            let mut scales = vec![0.0f64; 64];
            for (i, &e) in g.table.entries.iter().enumerate() {
                scales[i] = gsem::formats::ieee::ldexp(1.0, e as i32 - 1075);
            }
            let w = |v: &[u16]| v.iter().map(|&x| x as u32).collect::<Vec<u32>>();
            use gsem::runtime::executor::Arg;
            let k = engine.kernel("cg_run_head").expect("artifact");
            let out = k
                .run_f64(&[
                    Arg::U32(&w(&slab.heads)),
                    Arg::U32(&w(&slab.tail1)),
                    Arg::U32(&slab.tail2),
                    Arg::U32(&slab.exp_idx),
                    Arg::U32(&slab.cols),
                    Arg::F64(&scales),
                    Arg::F64(&b),
                ])
                .expect("pjrt execute");
            let head = g.at_level(Precision::Head);
            let rel = gsem::solvers::true_relres(&head, &out[0], &b);
            println!(
                "\nAOT Pallas cg_run_head via PJRT: 50 fused CG steps, relres={rel:.3e} \
                 (python only at build time — this executed from rust)"
            );
        }
        Ok(None) => println!("\n(artifacts not built; `make artifacts` enables the PJRT demo)"),
        Err(e) => eprintln!("engine error: {e:#}"),
    }
}
