//! Quickstart: encode data in GSE-SEM, inspect the shared-exponent
//! table, compare SpMV formats, and run the stepped mixed-precision CG —
//! the 2-minute tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use gsem::coordinator::{FormatChoice, SolveRequest, SolverKind};
use gsem::formats::{Precision, SemVector};
use gsem::solvers::stepped::SteppedParams;
use gsem::sparse::gen::fem::diffusion2d;
use gsem::spmv::{build_operators, max_abs_diff};
use gsem::util::Prng;
use std::sync::Arc;

fn main() {
    // --- 1. vectors: one stored copy, three read precisions -------------
    let mut rng = Prng::new(1);
    let data: Vec<f64> = (0..10_000).map(|_| rng.lognormal(0.0, 2.0)).collect();
    let enc = SemVector::encode(&data, 8);
    println!("GSE table (biased exponents + 1): {:?}", enc.table.entries);
    println!(
        "stored {} B (fp64 would be {} B)",
        enc.stored_bytes(),
        data.len() * 8
    );
    for lvl in Precision::LADDER {
        println!(
            "  level {:?}: read {:>6} B, max |err| = {:.3e}",
            lvl,
            enc.read_bytes(lvl),
            enc.max_abs_error(&data, lvl)
        );
    }

    // --- 2. matrices: the three-precision SpMV --------------------------
    let a = diffusion2d(48, 48, 8.0, 7);
    println!("\nmatrix: {}x{}, nnz {}", a.nrows, a.ncols, a.nnz());
    let x = vec![1.0; a.ncols];
    let ops = build_operators(&a, 8);
    let mut y64 = vec![0.0; a.nrows];
    ops[0].apply(&x, &mut y64);
    for op in &ops {
        let mut y = vec![0.0; a.nrows];
        op.apply(&x, &mut y);
        println!(
            "  {:<18} bytes/apply {:>8}  maxAbsErr {:.3e}",
            op.format().label(),
            op.matrix_bytes(),
            max_abs_diff(&y64, &y)
        );
    }

    // --- 3. the stepped mixed-precision solver (Algorithm 3) ------------
    let req = SolveRequest::new(
        "quickstart",
        Arc::new(a),
        SolverKind::Cg,
        FormatChoice::Stepped { k: 8, params: SteppedParams::cg_paper().scaled(0.02) },
    );
    let res = gsem::coordinator::jobs::dispatch(&req).expect("diffusion2d solves cleanly");
    println!(
        "\nstepped CG: converged={} iters={} relres(FP64)={:.2e} switches={:?}",
        res.outcome.converged, res.outcome.iters, res.relres_fp64, res.outcome.switches
    );
}
