//! Domain example: GMRES on circuit-simulation matrices (the adder_dcop
//! family analog) — the workload class where FP16 overflows and GSE-SEM
//! shines because conductances span many binades but cluster on few
//! exponents.
//!
//! Run: `cargo run --release --example gmres_circuit`

use gsem::coordinator::{FormatChoice, RhsSpec, SolveRequest, SolverKind};
use gsem::formats::{Precision, ValueFormat};
use gsem::solvers::stepped::SteppedParams;
use gsem::sparse::gen::circuit::{conductance_network, dcop};
use gsem::sparse::stats::matrix_stats;
use gsem::util::table::TextTable;
use std::sync::Arc;

fn main() {
    let systems = [
        ("add32-like", conductance_network(2480, 4, 3.0, 0.3, 8008)),
        ("dcop-like", dcop(880, 25, 8004)),
        ("widegap", conductance_network(1200, 6, 5.0, 0.2, 77)),
    ];

    for (name, a) in systems {
        let s = matrix_stats(&a);
        println!(
            "\n== {name}: {}x{} nnz {} | exponent entropy {:.2} bits, top-8 coverage {:.1}% ==",
            a.nrows,
            a.ncols,
            a.nnz(),
            s.entropy.exponent_bits,
            100.0 * s.topk[3]
        );
        let arc = Arc::new(a);
        let mut t = TextTable::new(&["format", "iters", "relres", "time(s)"]);
        for (label, fmt) in [
            ("FP64", FormatChoice::fixed(ValueFormat::Fp64)),
            ("FP16", FormatChoice::fixed(ValueFormat::Fp16)),
            ("BF16", FormatChoice::fixed(ValueFormat::Bf16)),
            ("GSE-head", FormatChoice::fixed(ValueFormat::GseSem(Precision::Head))),
            (
                "GSE-stepped",
                FormatChoice::Stepped {
                    k: 8,
                    params: SteppedParams::gmres_paper().scaled(0.01),
                },
            ),
        ] {
            let mut req = SolveRequest::new(label, Arc::clone(&arc), SolverKind::Gmres, fmt);
            req.rhs = RhsSpec::Random(1);
            req.max_iters = 3000;
            // keep breakdown rows in the table (the paper's "/" cells)
            let res = match gsem::coordinator::jobs::dispatch(&req) {
                Ok(r) => r,
                Err(gsem::coordinator::ServiceError::Breakdown(b)) => *b,
                Err(e) => panic!("{label}: {e}"),
            };
            t.row(&[
                label.to_string(),
                res.outcome.iters.to_string(),
                res.outcome.relres_label(),
                format!("{:.3}", res.outcome.seconds),
            ]);
        }
        t.print();
    }
}
