//! Format explorer: the §II motivation study on your own data — entropy
//! of value/exponent/mantissa populations, top-k exponent coverage, GSE
//! table extraction (exact vs sampled), and per-level representation
//! error, across the synthetic corpus classes.
//!
//! Run: `cargo run --release --example format_explorer [-- <name.mtx>]`

use gsem::formats::gse::{ExpHistogram, GseTable};
use gsem::formats::{Precision, SemVector};
use gsem::sparse::gen::corpus::{spmv_corpus, CorpusSize};
use gsem::sparse::stats::{matrix_stats, TOPK_LEVELS};
use gsem::util::table::TextTable;
use gsem::util::Prng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Some(path) = args.first() {
        let a = gsem::sparse::mm::read_path(std::path::Path::new(path)).expect("read mtx");
        explore("user matrix", &a);
        return;
    }

    // one representative per corpus class
    let corpus = spmv_corpus(CorpusSize::Small);
    for class in ["pde", "cfd", "fem", "circuit", "random"] {
        if let Some(m) = corpus.iter().filter(|m| m.class == class).last() {
            explore(&format!("{} ({})", m.name, m.class), &m.a);
        }
    }
}

fn explore(name: &str, a: &gsem::sparse::Csr) {
    let s = matrix_stats(a);
    println!("\n==== {name}: {}x{} nnz {} ====", a.nrows, a.ncols, a.nnz());
    println!(
        "entropy: values {:.2}  exponents {:.2}  mantissas {:.2} bits | {} distinct exponents",
        s.entropy.value_bits, s.entropy.exponent_bits, s.entropy.mantissa_bits,
        s.num_distinct_exponents
    );
    let mut t = TextTable::new(&["k", "coverage", "exact-hit", "head maxerr", "full maxerr"]);
    let mut hist = ExpHistogram::new();
    hist.push_all(&a.vals);
    for (i, &k) in TOPK_LEVELS.iter().enumerate() {
        let table = GseTable::from_histogram(&hist, k);
        let enc = SemVector::encode_with_table(&a.vals, table.clone());
        t.row(&[
            k.to_string(),
            format!("{:.4}", s.topk[i]),
            format!("{:.4}", table.exact_hit_ratio(&hist)),
            format!("{:.2e}", enc.max_abs_error(&a.vals, Precision::Head)),
            format!("{:.2e}", enc.max_abs_error(&a.vals, Precision::Full)),
        ]);
    }
    t.print();

    // sampled vs exact extraction (§III-B1)
    let mut rng = Prng::new(5);
    let exact = GseTable::from_values(&a.vals, 8);
    let sampled = GseTable::from_sampled_rows(
        |r| {
            let (lo, hi) = (a.rowptr[r], a.rowptr[r + 1]);
            &a.vals[lo..hi]
        },
        a.nrows,
        8,
        (a.nrows / 10).max(1),
        &mut rng,
    );
    let overlap = sampled.entries.iter().filter(|e| exact.entries.contains(e)).count();
    println!(
        "sampled extraction: {}/{} entries agree with exact single-pass analysis",
        overlap,
        exact.len()
    );
}
